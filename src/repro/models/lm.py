"""Unified decoder LM over all assigned architectures.

Layers are grouped into the arch's repeating *pattern* (gemma: 5 local +
1 global; jamba: 7 Mamba + 1 attention with MoE every 2nd layer; plain:
period 1) and scanned with stacked parameters — one ``lax.scan`` over
L/P groups keeps the HLO compact for 62-layer models and gives the
standard remat boundary.  Modality frontends (llava/musicgen) are stubs
per the assignment: ``input_specs`` feeds precomputed patch embeddings /
multi-stream token ids.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from . import attention as attn_mod
from . import mamba as mamba_mod
from . import xlstm as xlstm_mod
from .layers import apply_norm, mlp, mlp_params, norm_params
from .moe import moe, moe_params

N_PATCHES = 256          # llava vision-stub prefix length


def _constrain(x, kind: str):
    """Activation-sharding annotation — identity unless the trace runs
    inside ``repro.dist.sharding.activation_rules`` (§Perf arm)."""
    from repro.dist.sharding import constrain
    return constrain(x, kind)


@dataclass(frozen=True)
class LayerSpec:
    kind: str            # attn | mamba | mlstm
    window: int = 0
    use_moe: bool = False


def build_pattern(cfg: ModelConfig) -> list[LayerSpec]:
    if cfg.block_type == "xlstm":
        return [LayerSpec("mlstm")]
    if cfg.block_type == "jamba":
        p = cfg.attn_period
        specs = []
        for i in range(p):
            kind = "attn" if i == p - 1 else "mamba"
            specs.append(LayerSpec(kind, 0, cfg.n_experts > 0
                                   and i % cfg.moe_period == 1))
        return specs
    if cfg.local_global_period:
        p = cfg.local_global_period
        return [LayerSpec("attn", cfg.sliding_window if i < p - 1 else 0,
                          cfg.n_experts > 0)
                for i in range(p)]
    return [LayerSpec("attn", cfg.sliding_window,
                      cfg.n_experts > 0)]


def _dtype(cfg: ModelConfig):
    return jnp.bfloat16 if cfg.dtype == "bfloat16" else jnp.float32


class LM:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.pattern = build_pattern(cfg)
        P = len(self.pattern)
        self.n_groups = cfg.n_layers // P
        self.rest_specs = self.pattern[:cfg.n_layers % P]

    # ------------------------------------------------------------------ init
    def _layer_params(self, key, spec: LayerSpec, dtype):
        cfg = self.cfg
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {"ln1": norm_params(cfg, dtype)}
        if spec.kind == "attn":
            p["inner"] = attn_mod.attn_params(k1, cfg, dtype)
        elif spec.kind == "mamba":
            p["inner"] = mamba_mod.mamba_params(k1, cfg, dtype)
        else:
            p["inner"] = xlstm_mod.xlstm_params(k1, cfg, dtype)
        if cfg.d_ff:
            p["ln2"] = norm_params(cfg, dtype)
            p["mlp"] = (moe_params(k2, cfg, dtype) if spec.use_moe
                        else mlp_params(k2, cfg, dtype))
        return p

    def init(self, key) -> dict:
        cfg = self.cfg
        dtype = _dtype(cfg)
        keys = jax.random.split(key, 4 + len(self.pattern))
        d, V = cfg.d_model, cfg.vocab
        params: dict = {
            "embed": jax.random.normal(
                keys[0], (cfg.n_codebooks, V, d) if cfg.n_codebooks > 1
                else (V, d), dtype) * d ** -0.5,
            "final_norm": norm_params(cfg, dtype),
        }
        if not cfg.tie_embeddings:
            params["head"] = jax.random.normal(
                keys[1], (d, cfg.n_codebooks * V) if cfg.n_codebooks > 1
                else (d, V), dtype) * d ** -0.5
        blocks = []
        for i, spec in enumerate(self.pattern):
            gkeys = jax.random.split(keys[3 + i], self.n_groups)
            blocks.append(jax.vmap(
                lambda k: self._layer_params(k, spec, dtype))(gkeys))
        params["blocks"] = blocks
        if self.rest_specs:
            rkeys = jax.random.split(keys[2], len(self.rest_specs))
            params["rest"] = [self._layer_params(k, s, dtype)
                              for k, s in zip(rkeys, self.rest_specs)]
        return params

    # --------------------------------------------------------------- forward
    def _apply_layer(self, spec: LayerSpec, p, x, positions, cache=None,
                     decode=False, pos=None):
        cfg = self.cfg
        h = apply_norm(x, p["ln1"], cfg)
        aux = jnp.zeros((), jnp.float32)
        if spec.kind == "attn":
            if decode:
                y, cache = attn_mod.decode_attention(
                    h, p["inner"], cfg, cache=cache, pos=pos,
                    window=spec.window)
            else:
                y, cache = attn_mod.attention(
                    h, p["inner"], cfg, positions=positions,
                    window=spec.window, cache=cache)
        elif spec.kind == "mamba":
            if decode:
                y, cache = mamba_mod.mamba_decode(h, p["inner"], cfg, cache)
            else:
                y, cache = mamba_mod.mamba(h, p["inner"], cfg, state=cache)
        else:
            if decode:
                y, cache = xlstm_mod.mlstm_decode(h, p["inner"], cfg, cache)
            else:
                y, cache = xlstm_mod.mlstm(h, p["inner"], cfg, state=cache)
        x = _constrain(x + y, "btd")
        if cfg.d_ff:
            h2 = apply_norm(x, p["ln2"], cfg)
            if spec.use_moe:
                y2, aux = moe(h2, p["mlp"], cfg)
            else:
                y2 = mlp(h2, p["mlp"], cfg.mlp_type)
            x = _constrain(x + y2, "btd")
        return x, cache, aux

    def _group_fn(self, decode: bool):
        def fn(carry, xs):
            x, positions, pos, aux = carry
            gp, gcache = xs
            new_caches = []
            for i, spec in enumerate(self.pattern):
                c = gcache[i] if gcache is not None else None
                x, c, a = self._apply_layer(spec, _index(gp, i), x,
                                            positions, cache=c,
                                            decode=decode, pos=pos)
                new_caches.append(c)
                aux = aux + a
            return (x, positions, pos, aux), new_caches
        return fn

    def _embed(self, params, tokens, prefix_emb=None):
        cfg = self.cfg
        if cfg.n_codebooks > 1:     # musicgen: (B, S, nc) summed streams
            x = sum(params["embed"][c][tokens[..., c]]
                    for c in range(cfg.n_codebooks))
        else:
            x = params["embed"][tokens]
        if prefix_emb is not None:  # llava: prepend patch embeddings
            x = jnp.concatenate([prefix_emb.astype(x.dtype), x], axis=1)
        return x

    def _logits(self, params, x):
        cfg = self.cfg
        head = params.get("head")
        if head is None:
            head = params["embed"].T
        out = _constrain(x @ head, "btv")
        if cfg.n_codebooks > 1:
            out = out.reshape(*x.shape[:-1], cfg.n_codebooks, cfg.vocab)
        return out

    def apply(self, params, tokens, *, prefix_emb=None, caches=None):
        """Full-sequence forward (train / prefill).
        Returns (logits, new_caches, moe_aux)."""
        cfg = self.cfg
        x = self._embed(params, tokens, prefix_emb)
        B, S = x.shape[:2]
        positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
        fn = self._group_fn(decode=False)
        if cfg.remat:
            fn = jax.checkpoint(fn)
        group_caches = caches["blocks"] if caches is not None else None
        carry = (x, positions, None, jnp.zeros((), jnp.float32))
        if self.n_groups and not cfg.scan_layers:
            # unrolled path (cost probes / small models): python loop
            outs = []
            for g in range(self.n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[g],
                                            params["blocks"])
                gc = (jax.tree_util.tree_map(lambda a: a[g], group_caches)
                      if group_caches is not None else None)
                carry, nc = fn(carry, (gp, gc))
                outs.append(nc)
            new_group_caches = (
                jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *outs)
                if group_caches is not None else None)
        elif self.n_groups:
            if group_caches is None:
                carry, _ = jax.lax.scan(
                    lambda c, gp: fn(c, (gp, None)), carry,
                    params["blocks"])
                new_group_caches = None
            else:
                carry, new_group_caches = jax.lax.scan(
                    fn, carry, (params["blocks"], group_caches))
        else:
            new_group_caches = group_caches
        x, _, _, aux = carry
        rest_caches = []
        for i, spec in enumerate(self.rest_specs):
            c = caches["rest"][i] if caches is not None else None
            x, c, a = self._apply_layer(spec, params["rest"][i], x,
                                        positions, cache=c)
            aux = aux + a
            rest_caches.append(c)
        x = apply_norm(x, params["final_norm"], cfg)
        logits = self._logits(params, x)
        new_caches = None
        if caches is not None:
            new_caches = {"blocks": new_group_caches, "rest": rest_caches}
        return logits, new_caches, aux

    def decode_step(self, params, caches, token, pos):
        """One decode step.  token: (B, 1) (or (B,1,nc)); pos: scalar.
        Returns (logits (B,1,V...), new caches)."""
        cfg = self.cfg
        x = self._embed(params, token)
        B = x.shape[0]
        positions = jnp.full((B, 1), pos, jnp.int32)
        fn = self._group_fn(decode=True)
        carry = (x, positions, pos, jnp.zeros((), jnp.float32))
        if self.n_groups and not cfg.scan_layers:
            outs = []
            for g in range(self.n_groups):
                gp = jax.tree_util.tree_map(lambda a: a[g],
                                            params["blocks"])
                gc = jax.tree_util.tree_map(lambda a: a[g],
                                            caches["blocks"])
                carry, nc = fn(carry, (gp, gc))
                outs.append(nc)
            new_group_caches = jax.tree_util.tree_map(
                lambda *xs: jnp.stack(xs), *outs)
        else:
            carry, new_group_caches = jax.lax.scan(
                fn, carry, (params["blocks"], caches["blocks"]))
        x, _, _, _ = carry
        rest_caches = []
        for i, spec in enumerate(self.rest_specs):
            x, c, _ = self._apply_layer(spec, params["rest"][i], x,
                                        positions, cache=caches["rest"][i],
                                        decode=True, pos=pos)
            rest_caches.append(c)
        x = apply_norm(x, params["final_norm"], cfg)
        logits = self._logits(params, x)
        return logits, {"blocks": new_group_caches, "rest": rest_caches}

    # ---------------------------------------------------------------- caches
    def _layer_cache(self, spec: LayerSpec, batch: int, max_len: int, dtype):
        cfg = self.cfg
        if spec.kind == "attn":
            return attn_mod.init_cache(cfg, batch, max_len, dtype)
        if spec.kind == "mamba":
            return mamba_mod.init_mamba_state(cfg, batch, dtype)
        return xlstm_mod.init_xlstm_state(cfg, batch)

    def init_cache(self, batch: int, max_len: int):
        dtype = _dtype(self.cfg)
        blocks = []
        for spec in self.pattern:
            one = self._layer_cache(spec, batch, max_len, dtype)
            blocks.append(jax.tree_util.tree_map(
                lambda a: jnp.broadcast_to(
                    a[None], (self.n_groups,) + a.shape), one))
        rest = [self._layer_cache(s, batch, max_len, dtype)
                for s in self.rest_specs]
        return {"blocks": blocks, "rest": rest}


def _index(tree, i: int):
    return tree[i] if isinstance(tree, (list, tuple)) else tree


# --------------------------------------------------------------------------
# loss
# --------------------------------------------------------------------------

def lm_loss(logits: jnp.ndarray, targets: jnp.ndarray,
            n_codebooks: int = 1) -> jnp.ndarray:
    """Causal cross-entropy (mean over tokens).  The softmax-CE row chain
    is a Row-template fusion site (see train driver)."""
    lf = logits.astype(jnp.float32)
    m = jnp.max(lf, axis=-1, keepdims=True)
    lse = m + jnp.log(jnp.sum(jnp.exp(lf - m), axis=-1, keepdims=True))
    tgt = jnp.take_along_axis(lf, targets[..., None], axis=-1)
    return jnp.mean(lse[..., 0] - tgt[..., 0])
