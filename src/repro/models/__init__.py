from .lm import LM, LayerSpec, build_pattern, lm_loss
